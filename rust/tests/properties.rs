//! Cross-module property tests (hand-rolled harness in `util::prop`):
//! randomized sweeps over the substrate invariants the coordinator relies
//! on. Each property prints a replayable seed on failure.

use ecco::net::{gaimd_weight, NetSim};
use ecco::runtime::native::{self, Exec};
use ecco::runtime::{CoalesceOpts, Engine, Labels, Task, TrainBatch};
use ecco::scene::{render, Frame, SceneState};
use ecco::server::eval_model;
use ecco::util::pool::Pool;
use ecco::util::{pool, prop};
use ecco::video::{transport_window, SamplingConfig, BPP_FLOOR, BPP_LOSSLESS};

#[test]
fn prop_gaimd_share_follows_alpha_over_one_minus_beta() {
    // Two flows with random GAIMD parameters on a shared bottleneck:
    // delivered-rate ratio tracks the weight law within a tolerance band.
    prop::check("gaimd-share-law", 12, |g| {
        let cap = g.f32(4.0, 20.0) as f64;
        let a1 = g.f32(0.5, 3.0) as f64;
        let a2 = g.f32(0.5, 3.0) as f64;
        let b = 0.5f64;
        let mut sim = NetSim::star(&[1e3, 1e3], cap);
        let f1 = sim.add_camera_flow(0, a1, b).map_err(|e| e.to_string())?;
        let f2 = sim.add_camera_flow(1, a2, b).map_err(|e| e.to_string())?;
        sim.run(80.0); // converge
        sim.reset_delivered();
        sim.run(120.0);
        let r1 = sim.delivered_mbit(f1);
        let r2 = sim.delivered_mbit(f2);
        let got = r1 / r2;
        let want = gaimd_weight(a1, b) / gaimd_weight(a2, b);
        let ratio = got / want;
        if !(0.55..=1.8).contains(&ratio) {
            return Err(format!(
                "share ratio {got:.2} vs law {want:.2} (x{ratio:.2}) a=({a1:.2},{a2:.2}) cap={cap:.1}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_goodput_bounded_by_every_link() {
    prop::check("goodput-capacity", 15, |g| {
        let n = g.usize(1, 5);
        let shared = g.f32(1.0, 10.0) as f64;
        let locals: Vec<f64> = (0..n).map(|_| g.f32(0.3, 8.0) as f64).collect();
        let mut sim = NetSim::star(&locals, shared);
        let ids: Vec<_> = (0..n)
            .map(|i| {
                sim.add_camera_flow(i, g.f32(0.3, 2.0) as f64, 0.5)
                    .unwrap()
            })
            .collect();
        sim.run(30.0);
        sim.reset_delivered();
        let dur = 40.0;
        sim.run(dur);
        let mut total = 0.0;
        for (i, &id) in ids.iter().enumerate() {
            let rate = sim.delivered_mbit(id) / dur;
            if rate > locals[i] * 1.02 {
                return Err(format!("flow {i} beat its uplink: {rate} > {}", locals[i]));
            }
            total += rate;
        }
        if total > shared * 1.02 {
            return Err(format!("aggregate {total} beat shared {shared}"));
        }
        Ok(())
    });
}

#[test]
fn prop_transport_conserves_frames_and_bits() {
    prop::check("transport-conservation", 60, |g| {
        let cfg = SamplingConfig {
            fps: g.f32(0.25, 12.0),
            res: [16, 32, 48][g.usize(0, 2)],
        };
        let secs = g.f32(5.0, 120.0) as f64;
        let mbit = g.f32(0.0, 200.0) as f64;
        let out = transport_window(cfg, secs, mbit);
        if out.frames_delivered > out.frames_sampled {
            return Err("delivered > sampled".into());
        }
        if !(0.0..=1.0).contains(&out.quality) {
            return Err(format!("quality out of range: {}", out.quality));
        }
        if out.frames_delivered > 0 {
            if out.bpp < BPP_FLOOR - 1e-9 || out.bpp > BPP_LOSSLESS + 1e-9 {
                return Err(format!("bpp out of range: {}", out.bpp));
            }
            // Bits used cannot exceed bits delivered.
            let used =
                out.bpp * (cfg.res * cfg.res * 3) as f64 * out.frames_delivered as f64;
            if used > mbit * 1e6 + 1.0 {
                return Err(format!("used {used} > delivered {}", mbit * 1e6));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_render_deterministic_and_bounded() {
    prop::check("render-determinism", 25, |g| {
        let mut state = SceneState::default_day();
        state.illumination = g.f32(0.25, 1.4);
        state.rain = g.f32(0.0, 1.0);
        state.hue_shift = g.f32(0.0, 1.0);
        state.clutter = g.f32(0.5, 4.0);
        state.clamp();
        let res = [16usize, 32, 48][g.usize(0, 2)];
        let seed = g.rng.next_u64();
        let a = render(&state, res, seed);
        let b = render(&state, res, seed);
        if a.pixels != b.pixels {
            return Err("same seed produced different pixels".into());
        }
        if a.pixels.iter().any(|&p| !(0.0..=1.0).contains(&p)) {
            return Err("pixel out of [0,1]".into());
        }
        if a.truth.objects.len() != b.truth.objects.len() {
            return Err("nondeterministic object population".into());
        }
        for o in &a.truth.objects {
            if o.class >= 4 || !(0.0..=1.0).contains(&o.cx) {
                return Err("invalid object".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_eval_matrix_equals_serial() {
    // The regroup fan-out's correctness contract: evaluating the full
    // (job x member) matrix on the worker pool yields exactly the serial
    // matrix, bit for bit, at any thread count. Inference is pure in
    // (theta, frames), so this is equality, not approximation.
    let engine = Engine::open_default().unwrap();
    let base = engine.init_model(Task::Det).unwrap().theta;
    prop::check("parallel-eval-matrix", 6, |g| {
        let n_jobs = g.usize(1, 3);
        let n_cams = g.usize(1, 4);
        let threads = g.usize(2, 6);
        let thetas: Vec<Vec<f32>> = (0..n_jobs)
            .map(|j| {
                let scale = 1.0 + j as f32 * g.f32(0.01, 0.2);
                base.iter().map(|&v| v * scale).collect()
            })
            .collect();
        let frame_sets: Vec<Vec<Frame>> = (0..n_cams)
            .map(|cam| {
                let salt = g.rng.next_u64();
                (0..4u64)
                    .map(|i| render(&SceneState::default_day(), 32, salt ^ (cam as u64 * 97 + i)))
                    .collect()
            })
            .collect();
        let pairs: Vec<(usize, usize)> = (0..n_jobs)
            .flat_map(|j| (0..n_cams).map(move |c| (j, c)))
            .collect();
        let serial: Vec<f32> = pairs
            .iter()
            .map(|&(j, c)| eval_model(&engine, Task::Det, &thetas[j], &frame_sets[c]).unwrap())
            .collect();
        let par = pool::try_map(threads, &pairs, |_, &(j, c)| {
            eval_model(&engine, Task::Det, &thetas[j], &frame_sets[c])
        })
        .map_err(|e| e.to_string())?;
        if par != serial {
            return Err(format!(
                "parallel matrix diverged (jobs={n_jobs} cams={n_cams} threads={threads})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_batch_sharded_kernels_bit_identical_to_serial() {
    // The sharded kernels' correctness contract: train-step gradients
    // (observed through theta/momentum after the update) and infer_batch
    // outputs at pool size 4 equal the serial pool-size-1 path bit for
    // bit, across random batches, tasks, and resolutions.
    let par_pool = Pool::new(3);
    prop::check("batch-shard-bit-identical", 6, |g| {
        let par = Exec {
            pool: &par_pool,
            threads: 4,
        };
        let r = [16usize, 32][g.usize(0, 1)];
        let b = native::TRAIN_BATCH;
        let seed = g.rng.next_u64();
        let pixels: Vec<f32> = (0..b * r * r * 3).map(|_| g.f32(0.0, 1.0)).collect();
        let seg_task = g.usize(0, 1) == 1;
        let (task, labels) = if seg_task {
            let sd = r / 4;
            let mut mask = vec![0.0f32; b * sd * sd * native::HEAD_OUT];
            for chunk in mask.chunks_mut(native::HEAD_OUT) {
                chunk[g.usize(0, native::HEAD_OUT - 1)] = 1.0;
            }
            (Task::Seg, Labels::Seg { mask })
        } else {
            let obj: Vec<f32> = (0..b * native::GRID * native::GRID)
                .map(|_| if g.usize(0, 2) == 0 { 1.0 } else { 0.0 })
                .collect();
            let mut cls = vec![0.0f32; b * native::GRID * native::GRID * native::K];
            for chunk in cls.chunks_mut(native::K) {
                chunk[g.usize(0, native::K - 1)] = 1.0;
            }
            (Task::Det, Labels::Det { obj, cls })
        };
        let batch = TrainBatch {
            res: r,
            pixels: pixels.clone(),
            labels,
        };
        let mut theta_s = native::he_init(task, seed);
        let mut mom_s = vec![0.0f32; theta_s.len()];
        let mut theta_p = theta_s.clone();
        let mut mom_p = mom_s.clone();
        let ser = Exec::serial();
        for step in 0..3 {
            let ls = native::train_step(task, &mut theta_s, &mut mom_s, &batch, b, 0.03, ser);
            let lp = native::train_step(task, &mut theta_p, &mut mom_p, &batch, b, 0.03, par);
            if ls.to_bits() != lp.to_bits() {
                return Err(format!("loss diverged at step {step}: {ls} vs {lp}"));
            }
        }
        if theta_s != theta_p || mom_s != mom_p {
            return Err(format!("params diverged (task {task:?}, r={r})"));
        }
        // Inference over the updated weights.
        let xi: Vec<f32> = (0..native::INFER_BATCH * r * r * 3)
            .map(|_| g.f32(0.0, 1.0))
            .collect();
        match task {
            Task::Det => {
                let (os, cs) = native::infer_det(&theta_s, &xi, native::INFER_BATCH, r, ser);
                let (op, cp) = native::infer_det(&theta_s, &xi, native::INFER_BATCH, r, par);
                if os != op || cs != cp {
                    return Err("infer_det diverged".into());
                }
            }
            Task::Seg => {
                let ps = native::infer_seg(&theta_s, &xi, native::INFER_BATCH, r, ser);
                let pp = native::infer_seg(&theta_s, &xi, native::INFER_BATCH, r, par);
                if ps != pp {
                    return Err("infer_seg diverged".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_microbatched_infer_bit_identical_to_per_call() {
    // The micro-batch submission layer's correctness contract: routing
    // concurrent infer calls through the coalescing queue — wide window
    // (groups actually merge) or zero window (opportunistic only), det
    // and seg interleaved, 2..4 OS-thread submitters on top of whatever
    // kernel pool ECCO_THREADS gave the engine — yields outputs
    // bit-identical to the per-call path. The native inference kernels
    // are per-sample pure, so a mega-batch is pure concatenation:
    // equality, not approximation.
    let engine = Engine::open_default().unwrap();
    let det_theta = engine.init_model(Task::Det).unwrap().theta;
    let seg_theta = engine.init_model(Task::Seg).unwrap().theta;
    let b = engine.manifest.infer_batch;
    prop::check("microbatch-bit-identical", 4, |g| {
        let r = [16usize, 32][g.usize(0, 1)];
        let n_subs = g.usize(2, 4);
        let sets: Vec<Vec<f32>> = (0..n_subs)
            .map(|_| (0..b * r * r * 3).map(|_| g.f32(0.0, 1.0)).collect())
            .collect();
        // Per-call reference: coalescing off (the shipping default).
        engine.set_coalesce(CoalesceOpts::default());
        let base: Vec<_> = sets
            .iter()
            .map(|px| {
                (
                    engine.infer_det(&det_theta, r, px).unwrap(),
                    engine.infer_seg(&seg_theta, r, px).unwrap(),
                )
            })
            .collect();
        for (tag, opts) in [
            ("wide", CoalesceOpts::on().window_us(50_000)),
            ("zero", CoalesceOpts::on().window_us(0)),
        ] {
            engine.set_coalesce(opts);
            let eng = &engine;
            let (dt, st) = (&det_theta[..], &seg_theta[..]);
            let outs: Vec<_> = std::thread::scope(|scope| {
                let handles: Vec<_> = sets
                    .iter()
                    .map(|px| {
                        scope.spawn(move || {
                            (
                                eng.infer_det(dt, r, px).unwrap(),
                                eng.infer_seg(st, r, px).unwrap(),
                            )
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            engine.set_coalesce(CoalesceOpts::default());
            for (i, (d, sg)) in outs.iter().enumerate() {
                if d.obj != base[i].0.obj || d.cls != base[i].0.cls {
                    return Err(format!(
                        "det diverged (r={r} subs={n_subs} window={tag})"
                    ));
                }
                if sg.probs != base[i].1.probs {
                    return Err(format!(
                        "seg diverged (r={r} subs={n_subs} window={tag})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mask_grid_consistent_with_contains() {
    prop::check("mask-contains-consistency", 25, |g| {
        let state = SceneState::default_day();
        let f = render(&state, 32, g.rng.next_u64());
        let s = 8;
        let mask = f.truth.mask_grid(s);
        for iy in 0..s {
            for ix in 0..s {
                let x = (ix as f32 + 0.5) / s as f32;
                let y = (iy as f32 + 0.5) / s as f32;
                let covered = f.truth.objects.iter().any(|o| o.contains(x, y));
                let labelled = mask[iy * s + ix] != 4;
                if covered != labelled {
                    return Err(format!("cell ({iy},{ix}): covered={covered} labelled={labelled}"));
                }
            }
        }
        Ok(())
    });
}

//! Tests for the `ecco::api` façade itself: RunSpec validation at the
//! session boundary, determinism of the event stream, and the JSONL sink.

use ecco::api::{run_fleet, JsonlSink, RunReport, RunSpec, RuntimeOpts, Session, SpecError};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;

/// A reduced-scale deterministic spec (2 cameras, 3 windows).
fn small_spec(seed: u64) -> RunSpec {
    RunSpec::new(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[2], 0.05, 20.0, seed))
        .gpus(1.0)
        .shared_mbps(10.0)
        .uplink_mbps(20.0)
        .windows(3)
        .seed(seed)
        .configure(|cfg| {
            cfg.micro_windows = 4;
            cfg.window_secs = 40.0;
            cfg.eval_frames = 8;
            cfg.pretrain_steps = 120;
        })
}

#[test]
fn invalid_specs_fail_before_any_engine_work() {
    // validate() reports the typed error...
    assert_eq!(
        RunSpec::new(Task::Det, Policy::ecco()).windows(0).validate(),
        Err(SpecError::NoWindows)
    );
    assert_eq!(
        RunSpec::new(Task::Det, Policy::ecco())
            .cams(4)
            .uplinks(vec![10.0; 3])
            .validate(),
        Err(SpecError::UplinkCountMismatch {
            cams: 4,
            uplinks: 3
        })
    );
    assert_eq!(
        RunSpec::new(Task::Det, Policy::ecco())
            .shared_mbps(0.0)
            .validate(),
        Err(SpecError::NonPositiveBandwidth(0.0))
    );
    // ...and Session::new surfaces it as an error (readable message).
    let mut engine = Engine::open_default().unwrap();
    let err = Session::new(
        &mut engine,
        RunSpec::new(Task::Det, Policy::ecco()).gpus(-2.0),
    )
    .err()
    .expect("invalid spec must not build a session");
    assert!(err.to_string().contains("gpus"), "{err}");
}

fn run_once(engine: &mut Engine, seed: u64) -> (RunReport, String) {
    let mut session = Session::new(engine, small_spec(seed)).unwrap();
    session.add_sink(Box::new(JsonlSink::new(Vec::<u8>::new())));
    let report = session.run().unwrap();
    let jsonl: String = report
        .events
        .iter()
        .map(|e| e.to_json().to_string_compact())
        .collect::<Vec<_>>()
        .join("\n");
    (report, jsonl)
}

#[test]
fn identical_spec_and_seed_reproduce_byte_identical_runs() {
    let mut engine = Engine::open_default().unwrap();
    let (a, a_log) = run_once(&mut engine, 31);
    let (b, b_log) = run_once(&mut engine, 31);

    // Byte-identical event logs...
    assert_eq!(a_log, b_log, "event streams must be reproducible");
    assert!(!a.events.is_empty(), "the run must emit events");
    assert_eq!(a.events, b.events);

    // ...and identical reports (modulo wall-clock time).
    assert_eq!(a.window_acc, b.window_acc);
    assert_eq!(a.cam_acc, b.cam_acc);
    assert_eq!(a.steady, b.steady);
    assert_eq!(a.final_acc, b.final_acc);
    assert_eq!(a.response_s, b.response_s);
    assert_eq!(a.satisfied, b.satisfied);
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.jobs, b.jobs);
    assert_eq!(a.alloc_log, b.alloc_log);
    assert_eq!(a.membership, b.membership);
}

#[test]
fn event_log_byte_identical_at_any_pool_size() {
    // The determinism contract of the eval fan-out: worker pools of 1 and
    // 4 threads must produce byte-identical event logs (index-ordered
    // reduction; no RNG is consumed on pool workers).
    let engine = Engine::open_default().unwrap();
    let run_with = |threads: usize| -> (RunReport, String) {
        let mut session =
            Session::new(&engine, small_spec(41).eval_threads(threads)).unwrap();
        let report = session.run().unwrap();
        let jsonl: String = report
            .events
            .iter()
            .map(|e| e.to_json().to_string_compact())
            .collect::<Vec<_>>()
            .join("\n");
        (report, jsonl)
    };
    let (a, a_log) = run_with(1);
    let (b, b_log) = run_with(4);
    assert!(!a.events.is_empty());
    assert_eq!(a_log, b_log, "pool size must not change the event stream");
    assert_eq!(a.window_acc, b.window_acc);
    assert_eq!(a.cam_acc, b.cam_acc);
    assert_eq!(a.alloc_log, b.alloc_log);
    assert_eq!(a.membership, b.membership);
}

#[test]
fn event_log_byte_identical_with_frame_cache_disabled() {
    // The eval-frame cache memoises pure renders of the frozen world
    // state, invalidated on every world advance — so disabling it must
    // not change a single byte of the run, including with the eval
    // fan-out active (cache hits happen on pool workers).
    // One arm uses the legacy setters, the other the RuntimeOpts batch —
    // also pinning that the deprecated wrappers and `runtime()` are the
    // same hook.
    let engine = Engine::open_default().unwrap();
    let run_with = |cache: bool| -> (RunReport, String) {
        let spec = if cache {
            small_spec(43).eval_threads(4).frame_cache(true)
        } else {
            small_spec(43).runtime(RuntimeOpts::new().threads(4).frame_cache(false))
        };
        let report = Session::new(&engine, spec).unwrap().run().unwrap();
        let jsonl: String = report
            .events
            .iter()
            .map(|e| e.to_json().to_string_compact())
            .collect::<Vec<_>>()
            .join("\n");
        (report, jsonl)
    };
    let (a, a_log) = run_with(true);
    let (b, b_log) = run_with(false);
    assert!(!a.events.is_empty());
    assert_eq!(a_log, b_log, "frame cache must not change the event stream");
    assert_eq!(a.window_acc, b.window_acc);
    assert_eq!(a.cam_acc, b.cam_acc);
    assert_eq!(a.alloc_log, b.alloc_log);
    assert_eq!(a.membership, b.membership);
}

#[test]
fn camera_builder_route_matches_uplinks_vector_byte_identically() {
    // Setting one camera's uplink through `.camera(..)` must be the same
    // run as the equivalent explicit `.uplinks(vec)` — the overrides layer
    // onto the resolved vector before the world is built.
    let engine = Engine::open_default().unwrap();
    let run_with = |spec: RunSpec| -> RunReport {
        Session::new(&engine, spec).unwrap().run().unwrap()
    };
    let via_vec = run_with(small_spec(45).uplinks(vec![20.0, 12.0]));
    let via_builder = run_with(small_spec(45).camera(1, |c| c.uplink_mbps(12.0)));
    assert!(!via_vec.events.is_empty());
    assert_eq!(via_vec.events, via_builder.events);
    assert_eq!(via_vec.window_acc, via_builder.window_acc);
    assert_eq!(via_vec.cam_acc, via_builder.cam_acc);
    assert_eq!(via_vec.alloc_log, via_builder.alloc_log);
    assert_eq!(via_vec.membership, via_builder.membership);
}

#[test]
fn camera_override_errors_surface_at_the_session_boundary() {
    let mut engine = Engine::open_default().unwrap();
    // validate() reports the typed errors...
    assert_eq!(
        small_spec(46).camera(9, |c| c.uplink_mbps(5.0)).validate(),
        Err(SpecError::UnknownCamera { cam: 9, cams: 2 })
    );
    assert_eq!(
        small_spec(46).camera(0, |c| c.window_len(-3.0)).validate(),
        Err(SpecError::ZeroWindowLen { cam: 0, secs: -3.0 })
    );
    assert_eq!(
        small_spec(46).camera(1, |c| c.window_len(10.0).phase(10.0)).validate(),
        Err(SpecError::PhaseOutOfRange {
            cam: 1,
            phase: 10.0,
            window_len: Some(10.0)
        })
    );
    // ...and Session::new surfaces them without building anything.
    let err = Session::new(&mut engine, small_spec(46).camera(9, |c| c.uplink_mbps(5.0)))
        .err()
        .expect("unknown camera override must not build a session");
    assert!(err.to_string().contains("camera override"), "{err}");
}

#[test]
fn fleet_reports_match_sequential_runs_in_spec_order() {
    let engine = Engine::open_default().unwrap();
    let seeds = [31u64, 32];
    let specs: Vec<RunSpec> = seeds.iter().map(|&s| small_spec(s)).collect();
    let fleet = run_fleet(&engine, specs, 4).unwrap();
    assert_eq!(fleet.len(), seeds.len());
    for (i, &seed) in seeds.iter().enumerate() {
        let seq = Session::new(&engine, small_spec(seed)).unwrap().run().unwrap();
        assert_eq!(fleet[i].events, seq.events, "seed {seed} diverged");
        assert_eq!(fleet[i].window_acc, seq.window_acc);
        assert_eq!(fleet[i].final_acc, seq.final_acc);
        assert_eq!(fleet[i].response_s, seq.response_s);
    }
}

#[test]
fn session_surfaces_uplink_scenario_mismatch_as_error() {
    // The old System::new asserted on this; it must be a typed validation
    // error at the façade, not a panic.
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[3], 0.05, 20.0, 9);
    let spec = RunSpec::new(Task::Det, Policy::ecco())
        .scenario(sc)
        .uplinks(vec![10.0; 5]);
    assert_eq!(
        spec.validate(),
        Err(SpecError::UplinkCountMismatch {
            cams: 3,
            uplinks: 5
        })
    );
    let sc = scenario::grouped_static(&[3], 0.05, 20.0, 9);
    let err = Session::new(
        &mut engine,
        RunSpec::new(Task::Det, Policy::ecco())
            .scenario(sc)
            .uplinks(vec![10.0; 5]),
    )
    .err()
    .expect("mismatched uplinks must not build a session");
    assert!(err.to_string().contains("uplink"), "{err}");
}

#[test]
fn different_seeds_diverge() {
    let mut engine = Engine::open_default().unwrap();
    let (_, a_log) = run_once(&mut engine, 31);
    let (_, b_log) = run_once(&mut engine, 32);
    assert_ne!(a_log, b_log, "different seeds should change the run");
}

#[test]
fn event_stream_reconstructs_legacy_logs_and_reports() {
    let mut engine = Engine::open_default().unwrap();
    let (report, _) = run_once(&mut engine, 33);
    // One WindowClosed per window, in order.
    assert_eq!(report.window_acc.len(), 3);
    assert_eq!(report.membership.len(), 3);
    assert_eq!(
        report.membership.iter().map(|(w, _)| *w).collect::<Vec<_>>(),
        vec![0, 1, 2]
    );
    // The alloc log covers only windows that had jobs, in window order.
    for win in report.alloc_log.windows(2) {
        assert!(win[0].0 <= win[1].0, "alloc log must be window-ordered");
    }
    // Per-camera series have one sample per window.
    assert_eq!(report.cam_acc.len(), 2);
    for series in &report.cam_acc {
        assert_eq!(series.len(), 3);
    }
}

#[test]
fn jsonl_file_sink_streams_the_run() {
    let mut engine = Engine::open_default().unwrap();
    let path = std::env::temp_dir().join(format!(
        "ecco_api_events_{}.jsonl",
        std::process::id()
    ));
    let path_str = path.to_str().unwrap().to_string();
    let mut session = Session::new(&mut engine, small_spec(34)).unwrap();
    session.add_sink(Box::new(JsonlSink::create(&path_str).unwrap()));
    let report = session.run().unwrap();
    // Sinks flush on drop (the session owns the sink box).
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), report.events.len());
    for line in lines {
        let j = ecco::util::json::Json::parse(line).unwrap();
        assert!(j.get("type").unwrap().as_str().is_ok());
    }
    let _ = std::fs::remove_file(&path);
}

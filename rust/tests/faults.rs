//! Integration tests for `ecco::faults`: the zero-cost guarantee of the
//! empty plan, graceful degradation under a dense fault schedule, and the
//! thread-count determinism of fault runs.

use ecco::api::{RunReport, RunSpec, Session};
use ecco::faults::{FaultPlan, FaultScenario};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;

const CAMS: usize = 4;
const WINDOWS: usize = 4;

/// A reduced-scale deterministic spec (4 cameras in two pairs, 4 windows).
fn small_spec(seed: u64) -> RunSpec {
    RunSpec::new(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[2, 2], 0.05, 20.0, seed))
        .gpus(1.0)
        .shared_mbps(10.0)
        .uplink_mbps(20.0)
        .windows(WINDOWS)
        .seed(seed)
        .configure(|cfg| {
            cfg.micro_windows = 4;
            cfg.window_secs = 40.0;
            cfg.eval_frames = 8;
            cfg.pretrain_steps = 120;
        })
}

fn heavy_plan(seed: u64) -> FaultPlan {
    FaultPlan::scenario(FaultScenario::Heavy, CAMS, WINDOWS, seed)
}

fn jsonl(report: &RunReport) -> String {
    report
        .events
        .iter()
        .map(|e| e.to_json().to_string_compact())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn empty_fault_plan_is_byte_identical_to_no_plan() {
    // The hard zero-cost rule: attaching FaultPlan::none() must not change
    // one byte of the event log relative to never mentioning faults.
    let engine = Engine::open_default().unwrap();
    let bare = Session::new(&engine, small_spec(31))
        .unwrap()
        .run()
        .unwrap();
    let none = Session::new(&engine, small_spec(31).faults(FaultPlan::none()))
        .unwrap()
        .run()
        .unwrap();
    assert!(!bare.events.is_empty());
    assert_eq!(
        jsonl(&bare),
        jsonl(&none),
        "FaultPlan::none() must be zero-cost"
    );
    assert_eq!(bare.window_acc, none.window_acc);
    assert_eq!(bare.cam_acc, none.cam_acc);
    assert_eq!(bare.alloc_log, none.alloc_log);
    assert_eq!(bare.membership, none.membership);
    // No plan → all-zero resilience metrics in both reports.
    assert_eq!(bare.resilience, none.resilience);
    assert_eq!(bare.resilience.fault_windows, 0);
    assert_eq!(bare.resilience.recoveries, 0);
}

#[test]
fn dense_fault_plan_completes_every_window_and_reports_resilience() {
    // The chaos-smoke guarantee: ≥30% of cameras flapping every window
    // plus one full uplink outage per window, and the run still completes
    // its whole horizon with the partition invariant intact.
    let engine = Engine::open_default().unwrap();
    let plan = heavy_plan(7);
    assert!(!plan.is_empty());
    let mut session = Session::new(&engine, small_spec(31).faults(plan)).unwrap();
    for w in 0..WINDOWS {
        let report = session.step_window().unwrap();
        assert_eq!(report.window, w);
        assert!(
            session.is_partition(),
            "window {w}: faults broke the one-job-per-camera partition"
        );
    }
    let kinds: Vec<&str> = session.events().iter().map(|e| e.kind()).collect();
    assert!(kinds.contains(&"camera_down"), "no dropout was injected");
    assert!(kinds.contains(&"camera_up"), "no rejoin was injected");
    assert!(kinds.contains(&"link_degraded"), "no uplink fault was injected");
    assert!(
        kinds.contains(&"fault_recovered"),
        "no recovery completed over {WINDOWS} windows"
    );
    let report = session.into_report();
    assert_eq!(report.window_acc.len(), WINDOWS, "every window must close");
    assert!(report.resilience.fault_windows > 0);
    assert!(report.resilience.acc_under_fault > 0.0);
    assert!(report.resilience.recoveries > 0);
    // The resilience metrics reach the results JSON.
    let json = report.to_json().to_string_compact();
    assert!(json.contains("\"fault_windows\""), "{json}");
    assert!(json.contains("\"windows_to_recover\""), "{json}");
}

#[test]
fn fault_runs_are_byte_identical_across_thread_counts() {
    // Fault runs inherit the determinism contract: same plan + same seed
    // must produce byte-identical event logs at eval pools of 1 and 4.
    let engine = Engine::open_default().unwrap();
    let run_with = |threads: usize| -> (RunReport, String) {
        let spec = small_spec(41)
            .faults(heavy_plan(11))
            .eval_threads(threads);
        let report = Session::new(&engine, spec).unwrap().run().unwrap();
        let log = jsonl(&report);
        (report, log)
    };
    let (a, a_log) = run_with(1);
    let (b, b_log) = run_with(4);
    assert!(a.events.iter().any(|e| e.kind() == "camera_down"));
    assert_eq!(a_log, b_log, "thread count changed a fault run's event log");
    assert_eq!(a.window_acc, b.window_acc);
    assert_eq!(a.resilience, b.resilience);
}

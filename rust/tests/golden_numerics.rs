//! Integration test: the Rust PJRT runtime reproduces the numerics that
//! jax computed at AOT time (artifacts/golden.json).
//!
//! Inputs are regenerated with the shared LCG (see aot.py `lcg_array` and
//! util::rng::GoldenLcg), so any disagreement isolates a runtime bug, a
//! manifest mismatch, or an artifact/text-roundtrip problem.
//!
//! Bit-exact golden comparison only makes sense against the PJRT backend
//! executing the actual AOT artifacts, so this whole suite is gated on
//! `--features pjrt` (the native backend matches the math but not the
//! float summation order). Individual tests additionally skip with a
//! message when `artifacts/` has not been generated.
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use ecco::runtime::{Engine, Labels, Task, TrainBatch};
use ecco::util::json::Json;
use ecco::util::rng::GoldenLcg;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn lcg(n: usize, seed: u32) -> Vec<f32> {
    GoldenLcg::new(seed).fill(n)
}

fn one_hot(idx: &[usize], k: usize) -> Vec<f32> {
    let mut out = vec![0.0; idx.len() * k];
    for (i, &c) in idx.iter().enumerate() {
        out[i * k + c % k] = 1.0;
    }
    out
}

/// Load golden.json + an engine, or skip (with a message) when the
/// artifacts have not been generated.
fn golden_setup() -> Option<(Json, Engine)> {
    let text = match std::fs::read_to_string(artifacts_dir().join("golden.json")) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("skipping: artifacts/ not generated (run `make artifacts`)");
            return None;
        }
    };
    let engine = match Engine::new(&artifacts_dir()) {
        Ok(e) => e,
        Err(_) => {
            eprintln!("skipping: artifacts/ incomplete (run `make artifacts`)");
            return None;
        }
    };
    Some((Json::parse(&text).unwrap(), engine))
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol + tol * w.abs(),
            "{what}[{i}]: got {g}, want {w}"
        );
    }
}

#[test]
fn det_train_matches_jax() {
    let Some((g, mut engine)) = golden_setup() else { return };
    let case = g.get("cases").unwrap().get("det").unwrap();
    let m = engine.manifest.clone();
    let (b, r, grid, k) = (m.train_batch, 32usize, m.grid, m.classes);

    let mut state = engine.init_model(Task::Det).unwrap();
    let x = lcg(b * r * r * 3, 7);
    let obj: Vec<f32> = lcg(b * grid * grid, 11)
        .into_iter()
        .map(|v| if v > 0.7 { 1.0 } else { 0.0 })
        .collect();
    let cls_idx: Vec<usize> = lcg(b * grid * grid, 13)
        .into_iter()
        .map(|v| (v * k as f32) as usize)
        .collect();
    let cls = one_hot(&cls_idx, k);
    let batch = TrainBatch {
        res: r,
        pixels: x,
        labels: Labels::Det { obj, cls },
    };

    let want_losses = case.get("losses").unwrap().f32_array().unwrap();
    let mut got_losses = Vec::new();
    for _ in 0..3 {
        got_losses.push(engine.train_step(&mut state, &batch, 0.05).unwrap());
    }
    assert_close(&got_losses, &want_losses, 2e-4, "det losses");

    let want_theta = case.get("theta_head8").unwrap().f32_array().unwrap();
    assert_close(&state.theta[..8], &want_theta, 2e-4, "det theta head");
    assert_eq!(state.steps, 3);
}

#[test]
fn seg_train_matches_jax() {
    let Some((g, mut engine)) = golden_setup() else { return };
    let case = g.get("cases").unwrap().get("seg").unwrap();
    let m = engine.manifest.clone();
    let (b, r, k) = (m.train_batch, 32usize, m.classes);
    let s = r / 4;

    let mut state = engine.init_model(Task::Seg).unwrap();
    let x = lcg(b * r * r * 3, 7);
    let mask_idx: Vec<usize> = lcg(b * s * s, 17)
        .into_iter()
        .map(|v| (v * (k + 1) as f32) as usize)
        .collect();
    let mask = one_hot(&mask_idx, k + 1);
    let batch = TrainBatch {
        res: r,
        pixels: x,
        labels: Labels::Seg { mask },
    };

    let want_losses = case.get("losses").unwrap().f32_array().unwrap();
    let mut got_losses = Vec::new();
    for _ in 0..3 {
        got_losses.push(engine.train_step(&mut state, &batch, 0.05).unwrap());
    }
    assert_close(&got_losses, &want_losses, 2e-4, "seg losses");
}

#[test]
fn det_infer_matches_jax() {
    let Some((g, mut engine)) = golden_setup() else { return };
    let case = g.get("cases").unwrap().get("det").unwrap();
    let m = engine.manifest.clone();
    let (b, r) = (m.infer_batch, 32usize);

    let state = engine.init_model(Task::Det).unwrap();
    let x = lcg(b * r * r * 3, 23);
    let pred = engine.infer_det(&state.theta, r, &x).unwrap();

    let want = case.get("infer_head8").unwrap().as_arr().unwrap();
    let want_obj = want[0].f32_array().unwrap();
    let want_cls = want[1].f32_array().unwrap();
    assert_close(&pred.obj[..8], &want_obj, 1e-4, "det infer obj");
    assert_close(&pred.cls[..8], &want_cls, 1e-4, "det infer cls");
    // Probabilities must be valid.
    assert!(pred.obj.iter().all(|p| (0.0..=1.0).contains(p)));
    for bidx in 0..pred.batch {
        let row: f32 = pred.cls_at(bidx, 0, 0).iter().sum();
        assert!((row - 1.0).abs() < 1e-4);
    }
}

#[test]
fn seg_infer_matches_jax() {
    let Some((g, mut engine)) = golden_setup() else { return };
    let case = g.get("cases").unwrap().get("seg").unwrap();
    let m = engine.manifest.clone();
    let (b, r) = (m.infer_batch, 32usize);

    let state = engine.init_model(Task::Seg).unwrap();
    let x = lcg(b * r * r * 3, 23);
    let pred = engine.infer_seg(&state.theta, r, &x).unwrap();
    let want = case.get("infer_head8").unwrap().as_arr().unwrap()[0]
        .f32_array()
        .unwrap();
    assert_close(&pred.probs[..8], &want, 1e-4, "seg infer");
    let row: f32 = pred.probs_at(0, 0, 0).iter().sum();
    assert!((row - 1.0).abs() < 1e-4);
}

#[test]
fn features_match_jax() {
    let Some((g, mut engine)) = golden_setup() else { return };
    let m = engine.manifest.clone();
    let x = lcg(m.infer_batch * 32 * 32 * 3, 29);
    let emb = engine.features(&x).unwrap();
    assert_eq!(emb.len(), m.infer_batch * m.embed_dim);
    let want = g.get("features").unwrap().get("head8").unwrap().f32_array().unwrap();
    assert_close(&emb[..8], &want, 1e-4, "features");
    // Unit norm per row.
    let norm: f32 = emb[..m.embed_dim].iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3, "norm={norm}");
}

#[test]
fn all_resolution_variants_execute() {
    let Some((_g, mut engine)) = golden_setup() else { return };
    let m = engine.manifest.clone();
    for task in [Task::Det, Task::Seg] {
        for &r in &m.resolutions.clone() {
            let mut state = engine.init_model(task).unwrap();
            let x = lcg(m.train_batch * r * r * 3, 31);
            let labels = match task {
                Task::Det => Labels::Det {
                    obj: vec![0.0; m.train_batch * m.grid * m.grid],
                    cls: vec![0.0; m.train_batch * m.grid * m.grid * m.classes],
                },
                Task::Seg => {
                    let s = r / 4;
                    let idx: Vec<usize> = vec![m.classes; m.train_batch * s * s];
                    Labels::Seg {
                        mask: one_hot(&idx, m.classes + 1),
                    }
                }
            };
            let batch = TrainBatch {
                res: r,
                pixels: x,
                labels,
            };
            let loss = engine.train_step(&mut state, &batch, 0.01).unwrap();
            assert!(loss.is_finite(), "{task:?} r{r} loss not finite");
        }
    }
}

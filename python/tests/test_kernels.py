"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value ranges; this is the core correctness
signal for the kernels that end up inside every HLO artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_matmul import fused_linear, matmul_bias_act
from compile.kernels.patchstats import patch_stats
from compile.kernels.ref import matmul_bias_act_ref, patch_stats_ref

settings.register_profile("kernels", deadline=None, max_examples=20)
settings.load_profile("kernels")


def _rand(shape, seed, lo=-2.0, hi=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, act, seed):
    x = _rand((m, k), seed)
    w = _rand((k, n), seed + 1)
    b = _rand((n,), seed + 2)
    got = matmul_bias_act(x, w, b, act)
    exp = matmul_bias_act_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1), (8, 8), (128, 128), (129, 3), (200, 130)])
def test_matmul_block_boundaries(shape):
    """Exact block multiples and off-by-one shapes around BLOCK_{M,N,K}."""
    m, k = shape
    n = 17
    x = _rand((m, k), 0)
    w = _rand((k, n), 1)
    b = _rand((n,), 2)
    got = matmul_bias_act(x, w, b, "relu")
    exp = matmul_bias_act_ref(x, w, b, "relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    x = _rand((4, 5), 0)
    w = _rand((6, 3), 1)
    b = _rand((3,), 2)
    with pytest.raises(ValueError):
        matmul_bias_act(x, w, b)
    with pytest.raises(ValueError):
        matmul_bias_act(x, _rand((5, 3), 1), _rand((4,), 2))
    with pytest.raises(ValueError):
        matmul_bias_act(x, _rand((5, 3), 1), b, "gelu")


@given(
    m=st.integers(2, 40),
    k=st.integers(2, 40),
    n=st.integers(2, 20),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_grads_match_ref(m, k, n, act, seed):
    x = _rand((m, k), seed)
    w = _rand((k, n), seed + 1)
    b = _rand((n,), seed + 2)

    def f(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def fr(x, w, b):
        return jnp.sum(matmul_bias_act_ref(x, w, b, act) ** 2)

    got = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    exp = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=1e-4, atol=1e-3)


@given(
    b=st.integers(1, 6),
    r=st.sampled_from([16, 32, 48]),
    seed=st.integers(0, 2**31 - 1),
)
def test_patch_stats_matches_ref(b, r, seed):
    x = _rand((b, r, r, 3), seed, lo=0.0, hi=1.0)
    got = patch_stats(x)
    exp = patch_stats_ref(x)
    assert got.shape == (b, 96)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-5)


def test_patch_stats_constant_frame_has_zero_std():
    x = jnp.ones((2, 32, 32, 3), jnp.float32) * 0.5
    e = np.asarray(patch_stats(x)).reshape(2, 16, 3, 2)
    np.testing.assert_allclose(e[..., 0], 0.5, atol=1e-6)
    np.testing.assert_allclose(e[..., 1], 1e-3, atol=1e-3)  # sqrt(eps)


def test_patch_stats_rejects_bad_shapes():
    with pytest.raises(ValueError):
        patch_stats(jnp.zeros((1, 30, 32, 3)))
    with pytest.raises(ValueError):
        patch_stats(jnp.zeros((1, 18, 18, 3)))  # not divisible by 4

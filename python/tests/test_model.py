"""L2 correctness: student model shapes, losses, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _det_batch(seed, b=model.TRAIN_BATCH, r=32):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    x = jax.random.uniform(k1, (b, r, r, 3))
    y_obj = (jax.random.uniform(k2, (b, model.GRID, model.GRID)) > 0.6).astype(
        jnp.float32
    )
    y_cls = jax.nn.one_hot(
        jax.random.randint(k3, (b, model.GRID, model.GRID), 0, model.K), model.K
    )
    return x, y_obj, y_cls


def test_param_layout_matches_count():
    for task in ("det", "seg"):
        layout = model.param_layout(task)
        total = sum(int(np.prod(s)) for _, s in layout)
        assert total == model.param_count(task)
        theta = model.init_params(0, task)
        assert theta.shape == (total,)
        d = model.unpack(theta, task)
        assert set(d) == {n for n, _ in layout}


def test_init_is_deterministic_and_seed_sensitive():
    a = np.asarray(model.init_params(7, "det"))
    b = np.asarray(model.init_params(7, "det"))
    c = np.asarray(model.init_params(8, "det"))
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0


@pytest.mark.parametrize("r", model.RESOLUTIONS)
def test_det_logits_shapes(r):
    theta = model.init_params(0, "det")
    x = jnp.zeros((2, r, r, 3))
    out = model.det_logits(theta, x)
    assert out.shape == (2, model.GRID, model.GRID, 1 + model.K)


@pytest.mark.parametrize("r", model.RESOLUTIONS)
def test_seg_logits_shapes(r):
    theta = model.init_params(0, "seg")
    x = jnp.zeros((2, r, r, 3))
    out = model.seg_logits(theta, x)
    assert out.shape == (2, r // 4, r // 4, model.K + 1)


def test_det_loss_finite_and_positive():
    theta = model.init_params(0, "det")
    x, y_obj, y_cls = _det_batch(0)
    loss = model.det_loss(theta, x, y_obj, y_cls)
    assert np.isfinite(float(loss)) and float(loss) > 0


def test_train_step_decreases_loss_det():
    theta = model.init_params(0, "det")
    mom = jnp.zeros_like(theta)
    x, y_obj, y_cls = _det_batch(1)
    losses = []
    for _ in range(6):
        theta, mom, loss = model.train_step(
            "det", theta, mom, x, y_obj, y_cls, jnp.float32(0.05)
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_train_step_decreases_loss_seg():
    theta = model.init_params(0, "seg")
    mom = jnp.zeros_like(theta)
    b, r = model.TRAIN_BATCH, 16
    s = r // 4
    k = jax.random.PRNGKey(2)
    x = jax.random.uniform(k, (b, r, r, 3))
    y = jax.nn.one_hot(
        jax.random.randint(jax.random.PRNGKey(3), (b, s, s), 0, model.K + 1),
        model.K + 1,
    )
    losses = []
    for _ in range(6):
        theta, mom, loss = model.train_step("seg", theta, mom, x, y, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_infer_outputs_are_probabilities():
    theta = model.init_params(0, "det")
    x = jax.random.uniform(jax.random.PRNGKey(4), (model.INFER_BATCH, 32, 32, 3))
    obj, cls = model.infer("det", theta, x)
    assert obj.shape == (model.INFER_BATCH, model.GRID, model.GRID)
    assert cls.shape == (model.INFER_BATCH, model.GRID, model.GRID, model.K)
    assert float(jnp.min(obj)) >= 0 and float(jnp.max(obj)) <= 1
    np.testing.assert_allclose(np.asarray(cls.sum(-1)), 1.0, atol=1e-5)


def test_features_normalised():
    x = jax.random.uniform(jax.random.PRNGKey(5), (model.INFER_BATCH, 32, 32, 3))
    (emb,) = model.features(x)
    assert emb.shape == (model.INFER_BATCH, model.EMBED_DIM)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(emb), axis=-1), 1.0, atol=1e-4
    )


def test_same_weights_usable_across_resolutions():
    """Convs are size-agnostic: one theta serves all resolution variants."""
    theta = model.init_params(0, "det")
    for r in model.RESOLUTIONS:
        out = model.det_logits(theta, jnp.ones((1, r, r, 3)) * 0.3)
        assert np.isfinite(np.asarray(out)).all()


def test_grad_clip_bounds_update():
    theta = model.init_params(0, "det")
    mom = jnp.zeros_like(theta)
    # Pathological batch: huge values -> gradient should still be clipped.
    x = jnp.ones((model.TRAIN_BATCH, 16, 16, 3)) * 100.0
    y_obj = jnp.ones((model.TRAIN_BATCH, model.GRID, model.GRID))
    y_cls = jnp.tile(jnp.eye(model.K)[0], (model.TRAIN_BATCH, model.GRID, model.GRID, 1))
    theta2, mom2, loss = model.train_step(
        "det", theta, mom, x, y_obj, y_cls, jnp.float32(0.05)
    )
    # ||mom2|| = ||clipped grad|| <= GRAD_CLIP
    assert float(jnp.linalg.norm(mom2)) <= model.GRAD_CLIP + 1e-3
    assert np.isfinite(np.asarray(theta2)).all()

"""AOT pipeline consistency: manifest <-> model code <-> artifact files.

These tests run against an existing artifacts/ directory (they skip if
`make artifacts` has not been run) and pin the contract the Rust runtime
depends on.
"""

import json
import os

import numpy as np
import pytest

from compile import model
from compile.aot import lcg_array, train_specs, infer_specs

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_matches_model_constants(manifest):
    assert manifest["classes"] == model.K
    assert manifest["grid"] == model.GRID
    assert manifest["resolutions"] == list(model.RESOLUTIONS)
    assert manifest["train_batch"] == model.TRAIN_BATCH
    assert manifest["infer_batch"] == model.INFER_BATCH
    assert manifest["embed_dim"] == model.EMBED_DIM
    for task in ("det", "seg"):
        assert manifest["tasks"][task]["param_count"] == model.param_count(task)


def test_all_artifact_files_exist_and_nonempty(manifest):
    for name, spec in manifest["artifacts"].items():
        path = os.path.join(ART, spec["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 1000, f"{name} suspiciously small"


def test_artifact_signatures_match_model_specs(manifest):
    for task in ("det", "seg"):
        for r in model.RESOLUTIONS:
            spec = manifest["artifacts"][f"{task}_train_r{r}"]
            expect = [list(s.shape) for s in train_specs(task, r)]
            assert [i["shape"] for i in spec["inputs"]] == expect
            spec = manifest["artifacts"][f"{task}_infer_r{r}"]
            expect = [list(s.shape) for s in infer_specs(task, r)]
            assert [i["shape"] for i in spec["inputs"]] == expect


def test_init_params_roundtrip(manifest):
    for task in ("det", "seg"):
        path = os.path.join(ART, manifest["tasks"][task]["init_file"])
        raw = np.fromfile(path, dtype=np.float32)
        expect = np.asarray(model.init_params(manifest["init_seed"], task))
        np.testing.assert_array_equal(raw, expect)


def test_golden_losses_are_decreasing(manifest):
    with open(os.path.join(ART, "golden.json")) as f:
        golden = json.load(f)
    for task in ("det", "seg"):
        losses = golden["cases"][task]["losses"]
        assert len(losses) == 3
        assert losses[2] < losses[0], f"{task} golden losses must decrease"


def test_lcg_matches_documented_recurrence():
    vals = lcg_array((3,), seed=7)
    state = 7
    expect = []
    for _ in range(3):
        state = (1664525 * state + 1013904223) % 2**32
        expect.append(np.float32(state) / np.float32(2**32))
    np.testing.assert_allclose(vals, np.array(expect, dtype=np.float32), rtol=1e-6)


def test_hlo_text_artifacts_are_parseable_headers(manifest):
    """HLO text must start with the module header the Rust loader expects."""
    for name, spec in manifest["artifacts"].items():
        path = os.path.join(ART, spec["file"])
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), f"{name}: {head[:40]!r}"

"""L2: the student model (JAX), built on the L1 Pallas kernels.

This is ECCO's retrained "student": a tiny convolutional detector in the
spirit of YOLO11n (see DESIGN.md for the substitution argument). Every
convolution is expressed as im2col followed by the fused Pallas matmul
kernel, so the L1 kernel is on the hot path of both forward and backward.

Two task heads share the trunk:
  * det -- per-cell objectness + class logits on a GRID x GRID grid
           (grid-cell detection; scored by mAP in the Rust coordinator).
  * seg -- per-cell (K+1)-class logits at the trunk's finest spatial
           resolution (R/4 x R/4), a coarse instance-mask task.

Parameters (and SGD momentum) live in ONE flat f32 vector so the Rust
runtime handles exactly two device-resident buffers per model; the layout
is recorded in artifacts/manifest.json by aot.py.

All functions here are pure and jit/lower-friendly; aot.py lowers
train_step / infer / features to HLO text once per (task, resolution).
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.fused_matmul import fused_linear
from .kernels.patchstats import patch_stats

K = 4  # object classes
GRID = 4  # detection grid (GRID x GRID cells)
MOMENTUM = 0.9
GRAD_CLIP = 5.0
RESOLUTIONS = (16, 32, 48)
TRAIN_BATCH = 8
INFER_BATCH = 16
FEATURE_RES = 32
EMBED_DIM = 96  # patch_stats output: 4*4 patches * 3 ch * 2 moments

# (name, (in_features, out_features)) for the conv trunk; convs are 3x3.
TRUNK = [
    ("conv1", (3 * 9, 8)),
    ("conv2", (8 * 9, 16)),
    ("conv3", (16 * 9, 32)),
]
HEAD_OUT = {"det": 1 + K, "seg": K + 1}


def param_layout(task: str):
    """[(name, shape)] in flat-vector order."""
    layout = []
    for name, (fin, fout) in TRUNK:
        layout.append((f"{name}_w", (fin, fout)))
        layout.append((f"{name}_b", (fout,)))
    layout.append(("head_w", (32, HEAD_OUT[task])))
    layout.append(("head_b", (HEAD_OUT[task],)))
    return layout


def param_count(task: str) -> int:
    total = 0
    for _, shape in param_layout(task):
        size = 1
        for d in shape:
            size *= d
        total += size
    return total


def unpack(theta: jax.Array, task: str):
    """Flat f32 vector -> dict of named parameter arrays (static slices)."""
    out, off = {}, 0
    for name, shape in param_layout(task):
        size = 1
        for d in shape:
            size *= d
        out[name] = theta[off : off + size].reshape(shape)
        off += size
    return out


def init_params(seed: int, task: str) -> jax.Array:
    """He-init flat parameter vector (deterministic in `seed`)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_layout(task):
        key, sub = jax.random.split(key)
        if name.endswith("_w"):
            fan_in = shape[0]
            w = jax.random.normal(sub, shape) * jnp.sqrt(2.0 / fan_in)
            chunks.append(w.reshape(-1))
        else:
            chunks.append(jnp.zeros(shape).reshape(-1))
    return jnp.concatenate(chunks).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Trunk
# ---------------------------------------------------------------------------


def _im2col3x3(x: jax.Array) -> jax.Array:
    """[B,H,W,C] -> [B,H,W,9C] SAME-padded 3x3 patches (9 static slices)."""
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(xp[:, dy : dy + h, dx : dx + w, :])
    return jnp.concatenate(cols, axis=-1)


def _conv3x3(x, w, b, activation="relu"):
    """3x3 SAME conv via im2col x fused Pallas matmul."""
    bsz, h, wd, _ = x.shape
    patches = _im2col3x3(x).reshape(bsz * h * wd, -1)
    y = fused_linear(patches, w, b, activation)
    return y.reshape(bsz, h, wd, w.shape[1])


def _pool2(x):
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def trunk(theta_d, x):
    """[B,R,R,3] -> [B,R/4,R/4,32] feature map."""
    h = _conv3x3(x, theta_d["conv1_w"], theta_d["conv1_b"])
    h = _pool2(h)
    h = _conv3x3(h, theta_d["conv2_w"], theta_d["conv2_b"])
    h = _pool2(h)
    h = _conv3x3(h, theta_d["conv3_w"], theta_d["conv3_b"])
    return h


def _grid_pool(h, grid=GRID):
    """Average-pool a [B,S,S,C] map down to [B,grid,grid,C]."""
    b, s, _, c = h.shape
    f = s // grid
    return h.reshape(b, grid, f, grid, f, c).mean(axis=(2, 4))


def _head(h, theta_d):
    """1x1 conv head via the fused kernel: [B,S,S,32] -> [B,S,S,out]."""
    b, s, _, c = h.shape
    y = fused_linear(
        h.reshape(b * s * s, c), theta_d["head_w"], theta_d["head_b"], "none"
    )
    return y.reshape(b, s, s, -1)


def det_logits(theta: jax.Array, x: jax.Array) -> jax.Array:
    d = unpack(theta, "det")
    return _head(_grid_pool(trunk(d, x)), d)  # [B,GRID,GRID,1+K]


def seg_logits(theta: jax.Array, x: jax.Array) -> jax.Array:
    d = unpack(theta, "seg")
    return _head(trunk(d, x), d)  # [B,R/4,R/4,K+1]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def det_loss(theta, x, y_obj, y_cls):
    """BCE(objectness) + objectness-masked CE(class).

    y_obj: [B,GRID,GRID] in {0,1};  y_cls: [B,GRID,GRID,K] one-hot.
    """
    logits = det_logits(theta, x)
    lo = logits[..., 0]
    bce = jnp.maximum(lo, 0.0) - lo * y_obj + jnp.log1p(jnp.exp(-jnp.abs(lo)))
    bce = bce.mean()
    lc = jax.nn.log_softmax(logits[..., 1:], axis=-1)
    ce = -(y_cls * lc).sum(axis=-1)
    ce = (ce * y_obj).sum() / (y_obj.sum() + 1e-6)
    return bce + ce


def seg_loss(theta, x, y_mask):
    """CE over every mask cell. y_mask: [B,S,S,K+1] one-hot."""
    lm = jax.nn.log_softmax(seg_logits(theta, x), axis=-1)
    return -(y_mask * lm).sum(axis=-1).mean()


_LOSS = {"det": det_loss, "seg": seg_loss}


# ---------------------------------------------------------------------------
# Train / infer / features entry points (these get lowered by aot.py)
# ---------------------------------------------------------------------------


def _clip_by_norm(g, max_norm):
    norm = jnp.sqrt(jnp.sum(g * g) + 1e-12)
    return g * jnp.minimum(1.0, max_norm / norm)


def train_step(task: str, theta, mom, x, *labels_and_lr):
    """One SGD+momentum step.

    det: labels = (y_obj, y_cls);  seg: labels = (y_mask,). Final positional
    argument is the scalar learning rate.
    Returns (theta', mom', loss).
    """
    *labels, lr = labels_and_lr
    loss, grad = jax.value_and_grad(_LOSS[task])(theta, x, *labels)
    grad = _clip_by_norm(grad, GRAD_CLIP)
    mom = MOMENTUM * mom + grad
    theta = theta - lr * mom
    return theta, mom, loss


def infer(task: str, theta, x):
    """det -> (obj_prob [B,G,G], cls_prob [B,G,G,K]); seg -> (mask probs,)."""
    if task == "det":
        logits = det_logits(theta, x)
        return (
            jax.nn.sigmoid(logits[..., 0]),
            jax.nn.softmax(logits[..., 1:], axis=-1),
        )
    return (jax.nn.softmax(seg_logits(theta, x), axis=-1),)


def features(x):
    """[B,32,32,3] -> L2-normalised drift/grouping descriptors [B,96]."""
    e = patch_stats(x)
    return (e / (jnp.linalg.norm(e, axis=-1, keepdims=True) + 1e-8),)

"""AOT pipeline: lower every (task, resolution) model variant to HLO text.

Python runs ONLY here (``make artifacts``). The Rust coordinator loads the
emitted ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
never imports Python at runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (all under --out-dir, default ../artifacts):
  {det,seg}_train_r{16,32,48}.hlo.txt   (theta, mom, x, labels..., lr) ->
                                        (theta', mom', loss)
  {det,seg}_infer_r{16,32,48}.hlo.txt   (theta, x) -> probs...
  features_r32.hlo.txt                  (x,) -> (embeddings,)
  init_{det,seg}.bin                    raw little-endian f32 init params
  manifest.json                         shapes / layouts / hyperparams
  golden.json                           reference numerics for rust tests
"""

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

INIT_SEED = 1234


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def spec_json(specs):
    return [{"dtype": "f32", "shape": list(s.shape)} for s in specs]


def train_specs(task: str, r: int):
    p = model.param_count(task)
    b = model.TRAIN_BATCH
    base = [f32(p), f32(p), f32(b, r, r, 3)]
    if task == "det":
        g = model.GRID
        labels = [f32(b, g, g), f32(b, g, g, model.K)]
    else:
        s = r // 4
        labels = [f32(b, s, s, model.K + 1)]
    return base + labels + [f32()]


def infer_specs(task: str, r: int):
    return [f32(model.param_count(task)), f32(model.INFER_BATCH, r, r, 3)]


def lcg_array(shape, seed: int) -> np.ndarray:
    """Deterministic pseudo-random f32 in [0,1), reproducible bit-for-bit in
    Rust (same LCG): x_{n+1} = 1664525*x_n + 1013904223 (mod 2^32)."""
    n = int(np.prod(shape))
    out = np.empty(n, dtype=np.float32)
    state = np.uint32(seed)
    a, c = np.uint32(1664525), np.uint32(1013904223)
    with np.errstate(over="ignore"):
        for i in range(n):
            state = a * state + c
            out[i] = float(state) / 4294967296.0
    return out.reshape(shape)


def make_golden():
    """Reference numerics for the Rust integration tests.

    Inputs are LCG-generated (seed recorded) so Rust can regenerate them
    exactly; outputs are what jax computes for 3 train steps + one infer +
    one features call at r=32.
    """
    golden = {"lcg": {"a": 1664525, "c": 1013904223}, "cases": {}}
    r, b = 32, model.TRAIN_BATCH
    for task in ("det", "seg"):
        p = model.param_count(task)
        theta = model.init_params(INIT_SEED, task)
        mom = jnp.zeros(p, jnp.float32)
        x = jnp.asarray(lcg_array((b, r, r, 3), seed=7))
        if task == "det":
            g = model.GRID
            y_obj = (lcg_array((b, g, g), seed=11) > 0.7).astype(np.float32)
            cls_idx = (lcg_array((b, g, g), seed=13) * model.K).astype(np.int64)
            y_cls = np.eye(model.K, dtype=np.float32)[cls_idx % model.K]
            labels = [jnp.asarray(y_obj), jnp.asarray(y_cls)]
        else:
            s = r // 4
            m_idx = (lcg_array((b, s, s), seed=17) * (model.K + 1)).astype(np.int64)
            y_mask = np.eye(model.K + 1, dtype=np.float32)[m_idx % (model.K + 1)]
            labels = [jnp.asarray(y_mask)]
        lr = jnp.float32(0.05)
        losses = []
        for _ in range(3):
            theta, mom, loss = model.train_step(task, theta, mom, x, *labels, lr)
            losses.append(float(loss))
        xi = jnp.asarray(lcg_array((model.INFER_BATCH, r, r, 3), seed=23))
        outs = model.infer(task, model.init_params(INIT_SEED, task), xi)
        golden["cases"][task] = {
            "resolution": r,
            "train_seed_x": 7,
            "infer_seed_x": 23,
            "label_seeds": [11, 13] if task == "det" else [17],
            "lr": 0.05,
            "losses": losses,
            "theta_head8": [float(v) for v in np.asarray(theta[:8])],
            "infer_head8": [
                [float(v) for v in np.asarray(o).reshape(-1)[:8]] for o in outs
            ],
        }
    xe = jnp.asarray(lcg_array((model.INFER_BATCH, 32, 32, 3), seed=29))
    (emb,) = model.features(xe)
    golden["features"] = {
        "seed_x": 29,
        "head8": [float(v) for v in np.asarray(emb).reshape(-1)[:8]],
    }
    return golden


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--golden", action="store_true", default=True)
    ap.add_argument("--no-golden", dest="golden", action="store_false")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "init_seed": INIT_SEED,
        "classes": model.K,
        "grid": model.GRID,
        "momentum": model.MOMENTUM,
        "grad_clip": model.GRAD_CLIP,
        "resolutions": list(model.RESOLUTIONS),
        "train_batch": model.TRAIN_BATCH,
        "infer_batch": model.INFER_BATCH,
        "feature_res": model.FEATURE_RES,
        "embed_dim": model.EMBED_DIM,
        "tasks": {},
        "artifacts": {},
    }

    for task in ("det", "seg"):
        manifest["tasks"][task] = {
            "param_count": model.param_count(task),
            "head_out": model.HEAD_OUT[task],
            "layout": [
                {"name": n, "shape": list(s)} for n, s in model.param_layout(task)
            ],
            "init_file": f"init_{task}.bin",
        }
        theta0 = np.asarray(model.init_params(INIT_SEED, task), dtype=np.float32)
        theta0.tofile(os.path.join(args.out_dir, f"init_{task}.bin"))

        for r in model.RESOLUTIONS:
            # --- train step ---
            specs = train_specs(task, r)
            fn = partial(model.train_step, task)
            lowered = jax.jit(fn).lower(*specs)
            name = f"{task}_train_r{r}"
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(to_hlo_text(lowered))
            out_specs = [specs[0], specs[1], f32()]
            manifest["artifacts"][name] = {
                "file": f"{name}.hlo.txt",
                "inputs": spec_json(specs),
                "outputs": spec_json(out_specs),
            }
            print(f"wrote {name}")

            # --- infer ---
            specs = infer_specs(task, r)
            fn = partial(model.infer, task)
            lowered = jax.jit(fn).lower(*specs)
            name = f"{task}_infer_r{r}"
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(to_hlo_text(lowered))
            b, g = model.INFER_BATCH, model.GRID
            if task == "det":
                outs = [f32(b, g, g), f32(b, g, g, model.K)]
            else:
                outs = [f32(b, r // 4, r // 4, model.K + 1)]
            manifest["artifacts"][name] = {
                "file": f"{name}.hlo.txt",
                "inputs": spec_json(specs),
                "outputs": spec_json(outs),
            }
            print(f"wrote {name}")

    # --- features ---
    specs = [f32(model.INFER_BATCH, model.FEATURE_RES, model.FEATURE_RES, 3)]
    lowered = jax.jit(model.features).lower(*specs)
    with open(os.path.join(args.out_dir, "features_r32.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["features_r32"] = {
        "file": "features_r32.hlo.txt",
        "inputs": spec_json(specs),
        "outputs": spec_json([f32(model.INFER_BATCH, model.EMBED_DIM)]),
    }
    print("wrote features_r32")

    if args.golden:
        golden = make_golden()
        with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
            json.dump(golden, f, indent=1)
        manifest["golden"] = "golden.json"
        print("wrote golden.json")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()

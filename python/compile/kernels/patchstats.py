"""L1: per-patch frame statistics as a Pallas kernel.

Produces the compact frame descriptor the Rust coordinator uses for drift
detection and camera grouping (cosine distance between descriptors). A
frame [R, R, 3] is split into a PATCHES x PATCHES grid; each patch
contributes per-channel (mean, std), giving an embedding of
PATCHES * PATCHES * 3 * 2 floats.

The kernel runs one grid step per (frame, patch-row) and reduces a
VMEM-resident stripe of the image, which is the natural TPU layout: the
stripe is a contiguous HBM->VMEM block and both moments come out of a
single pass (sum / sum-of-squares), i.e. one read of the pixels.

interpret=True as everywhere (see fused_matmul.py).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PATCHES = 4  # descriptor grid; embedding dim = PATCHES^2 * 3 * 2
EPS = 1e-6


def _patchstats_kernel(x_ref, o_ref, *, patch: int, patches: int):
    """x_ref: [1, patch, R, 3] stripe (one patch-row); o_ref: [1, 1, patches, 3, 2]."""
    x = x_ref[...]  # (1, patch, R, 3)
    # Split the stripe into `patches` column-patches of width `patch`.
    x = x.reshape(patch, patches, patch, 3)
    n = float(patch * patch)
    s1 = jnp.sum(x, axis=(0, 2)) / n  # (patches, 3) mean
    s2 = jnp.sum(x * x, axis=(0, 2)) / n  # (patches, 3) E[x^2]
    var = jnp.maximum(s2 - s1 * s1, 0.0)
    stats = jnp.stack([s1, jnp.sqrt(var + EPS)], axis=-1)  # (patches, 3, 2)
    o_ref[...] = stats.reshape(1, 1, patches, 3, 2)


def patch_stats(x: jax.Array, patches: int = PATCHES) -> jax.Array:
    """x: [B, R, R, 3] -> descriptors [B, patches*patches*6] (f32).

    R must be divisible by `patches` (all supported resolutions are).
    """
    b, r, r2, c = x.shape
    if r != r2 or c != 3:
        raise ValueError(f"expected [B,R,R,3], got {x.shape}")
    if r % patches != 0:
        raise ValueError(f"R={r} not divisible by patches={patches}")
    patch = r // patches

    out = pl.pallas_call(
        partial(_patchstats_kernel, patch=patch, patches=patches),
        grid=(b, patches),
        in_specs=[
            pl.BlockSpec((1, patch, r, 3), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, patches, 3, 2), lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, patches, patches, 3, 2), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
    return out.reshape(b, patches * patches * 6)

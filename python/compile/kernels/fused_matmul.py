"""L1: fused tiled matmul + bias + activation as a Pallas kernel.

This is the compute hot-spot of the whole stack: every convolution in the
student model lowers to im2col followed by this kernel, and the detection /
segmentation heads call it directly (1x1 conv == per-cell dense).

TPU mapping (see DESIGN.md "Hardware-Adaptation"): the paper's student
(YOLO11n) runs CUDA convs tiled over threadblocks + shared memory. Here the
same schedule is expressed with Pallas BlockSpecs: each (bm, bn) output tile
lives in VMEM while the k-loop streams (bm, bk) x (bk, bn) operand tiles
from HBM through the MXU; bias-add and activation are fused into the
epilogue so the accumulator never round-trips HBM.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute. Correctness is pinned against the pure-jnp
oracle in ref.py (pytest + hypothesis).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fallback block shape for large problems. 128x128 matches the MXU systolic
# array footprint (and the f32 VMEM tiling of (8, 128)).
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128

# Whole-operand budget: if the padded x/w/out tiles together fit in this many
# f32 elements (~6 MiB of the ~16 MiB per-core VMEM), run the matmul as a
# single grid step — one HBM->VMEM stream per operand, zero revisits. Every
# layer of the student model fits this budget at all supported resolutions,
# so the 128-tiling is only exercised by stress tests.
VMEM_F32_BUDGET = 1_572_864

_ACTIVATIONS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
}


def _matmul_kernel(x_ref, y_ref, b_ref, o_ref, *, nk: int, activation: str):
    """One (m, n, k) grid step: accumulate a bk-slice into the output tile.

    Grid order is (m, n, k) with k innermost, so o_ref for a given (m, n)
    tile is revisited across consecutive steps and can serve as the
    accumulator; the epilogue (bias + activation) fires on the last k step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        act = _ACTIVATIONS[activation]
        o_ref[...] = act(o_ref[...] + b_ref[...][None, :])


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "none",
    *,
    bm: int = BLOCK_M,
    bn: int = BLOCK_N,
    bk: int = BLOCK_K,
) -> jax.Array:
    """act(x @ w + b) with x:[m,k], w:[k,n], b:[n] -> [m,n] (f32).

    Operands are zero-padded up to block multiples (zero rows/cols do not
    perturb the product) and the result is sliced back, so arbitrary shapes
    are supported; the kernel itself only ever sees full tiles.
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    # Pick the schedule: whole-operand single-step when it fits the VMEM
    # budget (the common case for the student model), otherwise classic
    # 128-tiling. Blocks are rounded to multiples of 8 (f32 sublane tiling)
    # so pad overhead stays bounded.
    mp8, kp8, np8 = _round8(m), _round8(k), _round8(n)
    if mp8 * kp8 + kp8 * np8 + mp8 * np8 <= VMEM_F32_BUDGET:
        bm, bk, bn = mp8, kp8, np8
    else:
        bm = min(bm, mp8)
        bn = min(bn, np8)
        bk = min(bk, kp8)

    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bk), 1, bn)
    bp = _pad_to(b.astype(jnp.float32), 0, bn)

    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        partial(_matmul_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def _round8(v: int) -> int:
    return max(8, (v + 7) // 8 * 8)


# ---------------------------------------------------------------------------
# Differentiable wrapper: custom VJP re-expresses both gradient matmuls with
# the same fused kernel, so forward AND backward run on the L1 hot path.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation="none"):
    return matmul_bias_act(x, w, b, activation)


def _fused_linear_fwd(x, w, b, activation):
    y = matmul_bias_act(x, w, b, activation)
    # For relu the post-activation output is enough to reconstruct the mask.
    return y, (x, w, y)


def _fused_linear_bwd(activation, res, dy):
    x, w, y = res
    if activation == "relu":
        dy = dy * (y > 0.0).astype(dy.dtype)
    dx = matmul_bias_act(dy, w.T, jnp.zeros((w.shape[0],), jnp.float32), "none")
    dw = matmul_bias_act(x.T, dy, jnp.zeros((dy.shape[1],), jnp.float32), "none")
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)

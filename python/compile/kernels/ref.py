"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is the straightforward, obviously-correct formulation; the
pytest suite (python/tests/) asserts the Pallas kernels match these to
tight tolerances across hypothesis-generated shape/value sweeps.
"""

import jax.numpy as jnp


def matmul_bias_act_ref(x, w, b, activation="none"):
    """act(x @ w + b) -- oracle for fused_matmul.matmul_bias_act."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    y = y + b.astype(jnp.float32)[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def patch_stats_ref(x, patches=4, eps=1e-6):
    """Oracle for patchstats.patch_stats: per-patch (mean, std) descriptor."""
    b, r, _, _ = x.shape
    patch = r // patches
    x = x.astype(jnp.float32).reshape(b, patches, patch, patches, patch, 3)
    mean = x.mean(axis=(2, 4))  # (b, patches, patches, 3)
    var = jnp.maximum((x * x).mean(axis=(2, 4)) - mean * mean, 0.0)
    std = jnp.sqrt(var + eps)
    out = jnp.stack([mean, std], axis=-1)  # (b, patches, patches, 3, 2)
    return out.reshape(b, patches * patches * 6)

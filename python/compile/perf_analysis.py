"""L1/L2 performance analysis (build-time).

Pallas runs under interpret=True on this CPU testbed, so TPU performance is
*estimated structurally* rather than measured (see the system prompt's
hardware note): for every matmul the student model issues, this script
reports the padded tile shapes the kernel's BlockSpecs produce, the VMEM
footprint of one grid step, and the MXU utilisation bound implied by the
operand geometry. It also audits the lowered HLO artifacts (op histogram,
fusion count) as the L2 profile.

Usage:  cd python && python -m compile.perf_analysis [--artifacts ../artifacts]
Output: a markdown table to paste into EXPERIMENTS.md §Perf.
"""

import argparse
import os
import re
from collections import Counter

from . import model
from .kernels import fused_matmul as fm

MXU_DIM = 128  # systolic array edge (lanes); f32 VMEM tiling is (8, 128)


def matmul_shapes(task: str, res: int):
    """Every (m, k, n) the model issues at this resolution (fwd pass)."""
    shapes = []
    b = model.TRAIN_BATCH
    h = res
    # conv1: im2col rows = B*H*W, k = 27, n = 8
    shapes.append((f"conv1 r{res}", b * h * h, 27, 8))
    h //= 2
    shapes.append((f"conv2 r{res}", b * h * h, 72, 16))
    h //= 2
    shapes.append((f"conv3 r{res}", b * h * h, 144, 32))
    out = model.HEAD_OUT[task]
    if task == "det":
        shapes.append((f"head r{res}", b * model.GRID * model.GRID, 32, out))
    else:
        shapes.append((f"head r{res}", b * h * h, 32, out))
    return shapes


def analyze_matmul(m, k, n):
    """Tile choice (mirrors fused_matmul), VMEM bytes, MXU utilisation."""
    mp, kp, np_ = fm._round8(m), fm._round8(k), fm._round8(n)
    whole = mp * kp + kp * np_ + mp * np_ <= fm.VMEM_F32_BUDGET
    if whole:
        bm, bk, bn = mp, kp, np_
        grid = 1
    else:
        bm = min(fm.BLOCK_M, mp)
        bk = min(fm.BLOCK_K, kp)
        bn = min(fm.BLOCK_N, np_)
        grid = -(-mp // bm) * -(-np_ // bn) * -(-kp // bk)
    vmem_bytes = 4 * (bm * bk + bk * bn + bm * bn + bn)
    # MXU utilisation bound: useful MACs / systolic-array MAC slots consumed.
    # The array is MXU_DIM x MXU_DIM; a (bm, bk) x (bk, bn) tile occupies
    # ceil(bk/128)*ceil(bn/128) passes of bm cycles each.
    import math

    passes = math.ceil(bk / MXU_DIM) * math.ceil(bn / MXU_DIM)
    slots = grid * passes * bm * MXU_DIM * MXU_DIM
    useful = m * k * n
    util = useful / slots
    return bm, bk, bn, grid, vmem_bytes, util


def hlo_stats(path):
    """Crude HLO-text op histogram (L2 fusion audit)."""
    ops = Counter()
    with open(path) as f:
        for line in f:
            m = re.match(r"\s*(%?[\w.-]+)\s*=\s*[\w\[\]{},:/ ]+\s(\w+)\(", line)
            if m:
                ops[m.group(2)] += 1
    return ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()

    print("## L1: Pallas fused-matmul schedule (per conv, forward pass)\n")
    print("| layer | m x k x n | tile (bm,bk,bn) | grid steps | VMEM/step | MXU util bound |")
    print("|-------|-----------|-----------------|------------|-----------|----------------|")
    for task, res in [("det", 32), ("det", 48)]:
        for name, m, k, n in matmul_shapes(task, res):
            bm, bk, bn, grid, vmem, util = analyze_matmul(m, k, n)
            print(
                f"| {name} | {m}x{k}x{n} | ({bm},{bk},{bn}) | {grid} |"
                f" {vmem/1024:.0f} KiB | {util*100:.1f}% |"
            )
    print(
        "\nNotes: one grid step per layer (whole-operand schedule) — each"
        " operand streams HBM->VMEM exactly once and bias+activation fuse"
        " into the epilogue. The MXU bound is set by n<=32 (<128 lanes);"
        " raising it requires wider channels or batched-layer fusion, i.e."
        " a bigger student — a model-capacity decision, not a kernel one."
    )

    print("\n## L2: lowered HLO op histogram (fusion audit)\n")
    for name in ["det_train_r32", "det_infer_r32", "features_r32"]:
        path = os.path.join(args.artifacts, f"{name}.hlo.txt")
        if not os.path.exists(path):
            print(f"(missing {path} — run make artifacts)")
            continue
        ops = hlo_stats(path)
        total = sum(ops.values())
        top = ", ".join(f"{op}:{c}" for op, c in ops.most_common(8))
        print(f"* `{name}`: {total} instructions — {top}")
    print(
        "\nXLA fuses elementwise chains around the dots after compilation;"
        " the interpret-mode pallas_call lowers to plain dot+elementwise HLO"
        " (single grid step), so no while-loop overhead survives into the"
        " compiled executable."
    )


if __name__ == "__main__":
    main()
